"""Attention: GQA (qk-norm / qkv-bias options) and MLA, with KV caches.

Prefill uses a memory-bounded *online-softmax chunked attention* (flash-
attention schedule in pure JAX lax): queries are processed in blocks and the
KV sequence is scanned with running (max, denominator) statistics, so the
full S×S score matrix is never materialized — required for the 32k-prefill
dry-run cells to fit HBM.

Caches:
  GQA: {"k": (B, S_max, Kv, D), "v": ..., } updated via dynamic slice.
  MLA: {"c_kv": (B, S_max, kv_lora), "k_pe": (B, S_max, rope_dim)} — the
       compressed cache that is MLA's reason to exist.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import (
    Boxed, apply_rope, dense_init, init_rmsnorm, rmsnorm, zeros_init,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked online-softmax attention core
# ---------------------------------------------------------------------------

def _attend_dense(q, k, v, mask, scale):
    """Reference einsum attention. q:(B,Sq,K,G,D) k/v:(B,Sk,K,D)."""
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


def chunked_attention(
    q: jax.Array,        # (B, Sq, H, D)
    k: jax.Array,        # (B, Sk, Kv, D)
    v: jax.Array,        # (B, Sk, Kv, D)
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    kv_valid_len: jax.Array | None = None,  # mask cache tail
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention; never materializes (Sq, Sk) at once."""
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qr = q.reshape(B, Sq, Kv, G, D)

    if Sq * Sk <= (q_chunk * kv_chunk):  # small: one dense block
        q_pos = q_offset + jnp.arange(Sq)
        k_pos = jnp.arange(Sk)
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if kv_valid_len is not None:
            mask &= k_pos[None, :] < kv_valid_len
        out = _attend_dense(qr, k, v, mask[None, None, None], scale)
        return out.reshape(B, Sq, H, Dv)

    if kv_valid_len is None and not isinstance(q_offset, jax.Array) \
            and q_offset == 0:
        # training/encoder path: flash attention (custom VJP, O(S) memory)
        from .flash import flash_attention
        out = flash_attention(qr, k, v, causal, q_chunk, kv_chunk)
        return out.reshape(B, Sq, H, Dv)

    # pad to chunk multiples
    def pad_seq(x, c):
        s = x.shape[1]
        r = s % c
        if r:
            x = jnp.pad(x, ((0, 0), (0, c - r)) + ((0, 0),) * (x.ndim - 2))
        return x

    qp = pad_seq(qr, q_chunk)
    kp = pad_seq(k, kv_chunk)
    vp = pad_seq(v, kv_chunk)
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qp = qp.reshape(B, nq, q_chunk, Kv, G, D)
    kp = kp.reshape(B, nk, kv_chunk, Kv, D)
    vp = vp.reshape(B, nk, kv_chunk, Kv, Dv)
    valid = kv_valid_len if kv_valid_len is not None else Sk

    def q_block(qb, qi):
        # qb: (B, q_chunk, Kv, G, D)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            acc, m, denom = carry
            kb, vb, ki = inp
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
            mask = (k_pos[None, :] < valid)
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            logits = logits.astype(jnp.float32)
            m_new = jnp.maximum(m, logits.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            denom = denom * alpha + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb)
            acc = acc * alpha[..., None].astype(acc.dtype) + pv
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, Kv, G, q_chunk, Dv), vp.dtype)
        m0 = jnp.full((B, Kv, G, q_chunk), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, Kv, G, q_chunk), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0),
            (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(denom, 1e-30)[..., None].astype(acc.dtype)
        return jnp.transpose(out, (0, 3, 1, 2, 4))  # (B,qc,Kv,G,D)

    outs = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.moveaxis(qp, 1, 0), jnp.arange(nq)),
    )  # (nq, B, qc, Kv, G, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_gqa(key, cfg) -> dict:
    d, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), ("embed", "heads", "head_dim")),
        "wk": dense_init(ks[1], (d, Kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": dense_init(ks[2], (d, Kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": dense_init(ks[3], (H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((H, hd), ("heads", "head_dim"))
        p["bk"] = zeros_init((Kv, hd), ("kv_heads", "head_dim"))
        p["bv"] = zeros_init((Kv, hd), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p["q_norm"] = {"scale": Boxed(jnp.ones((hd,)), ("head_dim",))}
        p["k_norm"] = {"scale": Boxed(jnp.ones((hd,)), ("head_dim",))}
    return p


def gqa_attention(
    params: dict,
    cfg,
    x: jax.Array,                    # (B, S, d)
    positions: jax.Array,            # (S,) absolute positions
    *,
    causal: bool = True,
    cache: dict | None = None,
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if getattr(cfg, "repeat_kv", False):
            # replicate kv heads to H: head reshapes stay (H,1) which keeps
            # the `model`-axis sharding intact (no (Kv,G) resharding gather)
            G = q.shape[2] // k.shape[2]
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        out = chunked_attention(q, k, v, causal=causal)
        new_cache = None
    else:
        idx = cache_index
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        # causal with q_offset handles both decode (S=1) and prefill (S>1)
        out = chunked_attention(
            q, ck, cv, causal=causal, q_offset=idx, kv_valid_len=idx + S,
        )
        new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_gqa_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — low-rank compressed KV
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dv = cfg.v_head_dim
    L = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, H, dn + dr), ("embed", "heads", "head_dim")),
        "w_dkv": dense_init(ks[1], (d, L), ("embed", "kv_lora")),
        "kv_norm": init_rmsnorm(L),
        "w_uk": dense_init(ks[2], (L, H, dn), ("kv_lora", "heads", "head_dim")),
        "w_uv": dense_init(ks[3], (L, H, dv), ("kv_lora", "heads", "head_dim")),
        "w_kpe": dense_init(ks[4], (d, dr), ("embed", "head_dim")),
        "wo": dense_init(ks[5], (H, dv, d), ("heads", "head_dim", "embed")),
    }


def mla_attention(
    params: dict,
    cfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    cache: dict | None = None,
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])   # (B,S,L)
    k_pe = apply_rope(
        (x @ params["w_kpe"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]                                               # (B,S,dr)

    if cache is not None:
        idx = cache_index
        c_kv_full = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0))
        k_pe_full = jax.lax.dynamic_update_slice(
            cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, idx, 0))
        valid = idx + S
        q_offset = idx
        new_cache = {"c_kv": c_kv_full, "k_pe": k_pe_full}
        causal_flag = causal
        if getattr(cfg, "mla_absorb", True) and S <= 16:
            # ABSORBED decode (hillclimb #1): reorder the factorized product
            # so the compressed cache is never decompressed — the same
            # multiplication-order insight as the paper's Theorem 1.
            #   scores = (q_nope·W_uk)·c_kvᵀ + q_pe·k_peᵀ ;
            #   out    = (probs·c_kv)·W_uv
            # Cost per token: O(H·(L+dr)·T) vs O(H·(dn+dr)·T + T·L·H·dn)
            # for decompress-then-attend.
            q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, params["w_uk"])
            scale = 1.0 / jnp.sqrt(dn + dr)
            s_nope = jnp.einsum(
                "bshl,btl->bhst", q_lat,
                c_kv_full.astype(q_lat.dtype))
            s_pe = jnp.einsum(
                "bshr,btr->bhst", q_pe, k_pe_full.astype(q_pe.dtype))
            logits = (s_nope + s_pe).astype(jnp.float32) * scale
            t_pos = jnp.arange(c_kv_full.shape[1])
            q_pos = idx + jnp.arange(S)
            bias = jnp.where(
                (t_pos[None, :] < valid) & (q_pos[:, None] >= t_pos[None, :]),
                0.0, -1e30)
            probs = jax.nn.softmax(logits + bias[None, None], axis=-1)
            o_lat = jnp.einsum(
                "bhst,btl->bshl", probs.astype(c_kv_full.dtype), c_kv_full)
            out = jnp.einsum("bshl,lhv->bshv", o_lat.astype(jnp.float32),
                             params["w_uv"].astype(jnp.float32))
            y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype),
                           params["wo"])
            return y, new_cache
    else:
        c_kv_full, k_pe_full = c_kv, k_pe
        valid = None
        q_offset = 0
        new_cache = None
        causal_flag = causal

    # absorb: decompress per use (training path); shapes stay (B,S,H,·)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv_full, params["w_uk"])
    vfull = jnp.einsum("bsl,lhk->bshk", c_kv_full, params["w_uv"])
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(
            k_pe_full[:, :, None, :], (*k_pe_full.shape[:2], H, dr))],
        axis=-1,
    )
    q_cat = jnp.concatenate([q_nope, q_pe], axis=-1)
    out = chunked_attention(
        q_cat, k_full, vfull, causal=causal_flag,
        q_offset=q_offset, kv_valid_len=valid,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }
