from .model import (
    cross_entropy_loss,
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
)
from .layers import Boxed, axes_tree, unbox

__all__ = [
    "cross_entropy_loss", "decode_step", "forward", "init_cache",
    "init_model", "loss_fn", "Boxed", "axes_tree", "unbox",
]
