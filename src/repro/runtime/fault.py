"""Fault tolerance + straggler mitigation for the training loop.

``Supervisor`` wraps the step loop:
  * checkpoint/restart — periodic async checkpoints; on a (simulated or
    real) failure the loop restores the latest commit and replays;
  * straggler watchdog — EWMA of step wall time; a step slower than
    ``straggler_factor``× the EWMA is logged and counted (on real fleets
    the hook triggers requeue/hot-spare swap; here it feeds metrics);
  * retry budget — repeated failures within a window abort with a clear
    error instead of looping forever.

At 1000+ node scale the same structure holds: the supervisor runs per-host,
checkpoints go to distributed storage (the CheckpointManager path becomes a
fuse/gcs mount), and failure detection comes from the coordinator barrier
timeout rather than an exception — the control flow here is the part that
must be correct, and it is testable on one host.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

from repro.checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_every: int = 100
    async_checkpoint: bool = True
    max_restarts: int = 5
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


@dataclasses.dataclass
class SupervisorStats:
    restarts: int = 0
    straggler_steps: int = 0
    checkpoints: int = 0
    ewma_step_s: float = 0.0


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, cfg: SupervisorConfig):
        self.ckpt = ckpt
        self.cfg = cfg
        self.stats = SupervisorStats()

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        num_steps: int,
        start_step: int = 0,
        state_shardings: Any = None,
    ) -> Any:
        """Run ``step_fn(state, i) -> state`` with restart-on-failure.

        On exception: restore latest checkpoint, resume from its step.
        """
        i = start_step
        restarts_left = self.cfg.max_restarts
        while i < num_steps:
            try:
                t0 = time.monotonic()
                state = step_fn(state, i)
                dt = time.monotonic() - t0
                st = self.stats
                if st.ewma_step_s == 0.0:
                    st.ewma_step_s = dt
                else:
                    a = self.cfg.ewma_alpha
                    if dt > self.cfg.straggler_factor * st.ewma_step_s:
                        st.straggler_steps += 1
                        log.warning(
                            "straggler step %d: %.3fs vs ewma %.3fs",
                            i, dt, st.ewma_step_s,
                        )
                    st.ewma_step_s = (1 - a) * st.ewma_step_s + a * dt
                i += 1
                if i % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(
                        i, state, blocking=not self.cfg.async_checkpoint)
                    self.stats.checkpoints += 1
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — restart-on-any-failure
                if restarts_left == 0:
                    raise RuntimeError(
                        f"supervisor: out of restarts at step {i}"
                    ) from e
                restarts_left -= 1
                self.stats.restarts += 1
                log.error("step %d failed (%s); restoring", i, e)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    log.error("no checkpoint to restore; restarting fresh")
                    i = start_step
                    continue
                state, i = self.ckpt.restore(
                    state, shardings=state_shardings)
        self.ckpt.wait()
        return state


class FailureInjector:
    """Deterministic failure injection for tests: raises at given steps."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.raised: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.raised:
            self.raised.add(step)
            raise RuntimeError(f"injected failure at step {step}")
