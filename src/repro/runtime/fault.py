"""Fault tolerance: injection plans, retry backoff, the training supervisor.

Three layers share this module:

  * ``FaultPlan`` — deterministic, seedable fault injection at named
    *sites* (``ingest`` / ``transfer`` / ``refresh`` / ``publish`` in the
    online-serving loop; any string works).  Each call to
    ``check(site)`` advances that site's counter and raises
    ``FaultInjected`` when the spec says so — either at targeted check
    indices (``hits``) or with a seeded per-site probability (``prob``).
    The same plan drives tests, the ``--inject-faults`` CLI flag, the
    ``RefreshSupervisor`` and the ``StratumPrefetcher``, so every
    failure-handling path is exercised by one mechanism.
  * ``backoff(attempt, ...)`` — the shared deterministic
    exponential-backoff-with-jitter schedule every retry loop uses.
  * ``Supervisor`` — the training-loop wrapper: checkpoint/restart on
    failure, straggler watchdog, retry budget.  (The *serving*-side
    refresh supervisor lives in ``repro.serve.supervisor`` — it degrades
    to stale tables instead of restoring checkpoints.)

At 1000+ node scale the same structure holds: the supervisor runs per-host,
checkpoints go to distributed storage (the CheckpointManager path becomes a
fuse/gcs mount), and failure detection comes from the coordinator barrier
timeout rather than an exception — the control flow here is the part that
must be correct, and it is testable on one host.
"""
from __future__ import annotations

import dataclasses
import logging
import time
import zlib
from typing import Any, Callable

import numpy as np

from repro.checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.fault")


# ---------------------------------------------------------------------------
# shared retry backoff
# ---------------------------------------------------------------------------

def backoff(attempt: int, base: float = 0.05, cap: float = 1.0,
            seed: int = 0) -> float:
    """Deterministic exponential backoff + jitter, in seconds.

    ``min(cap, base·2^attempt)`` scaled by a jitter factor in [0.5, 1.0)
    drawn from a ``(seed, attempt)``-keyed generator — so two runs with
    the same seed sleep the same schedule (reproducible tests), while
    different seeds decorrelate retry storms across workers.  Every
    retry loop in the repo (prefetcher transfers, the serve-side refresh
    supervisor) shares this one schedule.
    """
    if attempt < 0:
        raise ValueError(f"attempt must be ≥ 0, got {attempt}")
    span = min(float(cap), float(base) * (2.0 ** attempt))
    jitter = 0.5 + 0.5 * np.random.default_rng((seed, attempt)).random()
    return span * jitter


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

class FaultInjected(RuntimeError):
    """An injected (not organic) failure — raised by ``FaultPlan.check``."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One site's injection rule.

    ``hits``  — check indices (0-based, per-site counter) that raise;
                e.g. ``{0, 1, 2}`` fails the first three checks of the
                site then clears — the shape retry/breaker tests need.
    ``prob``  — additionally raise with this probability per check,
                from a ``(seed, site)``-keyed deterministic stream.
    """

    site: str
    hits: frozenset = frozenset()
    prob: float = 0.0

    def __post_init__(self):
        if not self.site:
            raise ValueError("FaultSpec needs a site name")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")


class FaultPlan:
    """Deterministic, seedable multi-site failure injection.

    The generalization of the old step-targeted ``FailureInjector``:
    faults are keyed by *site* (where in the pipeline) and fire either at
    targeted per-site check counts or probabilistically from a seeded
    stream — so a faulted run is exactly reproducible, and a retry loop
    that re-checks the site observes the fault clear at a known attempt.

        plan = FaultPlan([FaultSpec("ingest", hits={0, 1})], seed=0)
        plan.check("ingest")   # raises FaultInjected (check #0)
        plan.check("ingest")   # raises FaultInjected (check #1)
        plan.check("ingest")   # passes — the fault has cleared

    ``parse`` builds a plan from the ``--inject-faults`` CLI grammar:
    comma-separated ``site@i:j:k`` (targeted check indices) and/or
    ``site%p`` (probability) terms, e.g.
    ``"ingest@0:1,refresh@2,transfer%0.1,publish@0"``.
    """

    def __init__(self, specs=(), seed: int = 0):
        self.seed = int(seed)
        self._specs: dict[str, FaultSpec] = {}
        for s in specs:
            if s.site in self._specs:
                raise ValueError(f"duplicate FaultSpec for site {s.site!r}")
            self._specs[s.site] = s
        self._counts: dict[str, int] = {}
        self._fired: dict[str, int] = {}
        self._rngs: dict[str, np.random.Generator] = {}

    # -- construction ---------------------------------------------------------

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Build a plan from the CLI grammar (see class docstring)."""
        specs = []
        for term in filter(None, (t.strip() for t in text.split(","))):
            if "%" in term:
                site, _, p = term.partition("%")
                specs.append(FaultSpec(site, prob=float(p)))
            elif "@" in term:
                site, _, idxs = term.partition("@")
                hits = frozenset(int(i) for i in idxs.split(":") if i != "")
                if not hits:
                    raise ValueError(f"no check indices in {term!r}")
                specs.append(FaultSpec(site, hits=hits))
            else:
                raise ValueError(
                    f"bad fault term {term!r} (want site@i:j or site%p)")
        return cls(specs, seed=seed)

    # -- injection ------------------------------------------------------------

    def check(self, site: str) -> None:
        """Advance ``site``'s check counter; raise ``FaultInjected`` if
        the spec fires at this check.  Sites without a spec pass free
        (one dict lookup), so production code can leave checks in."""
        n = self._counts.get(site, 0)
        self._counts[site] = n + 1
        spec = self._specs.get(site)
        if spec is None:
            return
        fire = n in spec.hits
        if not fire and spec.prob:
            rng = self._rngs.get(site)
            if rng is None:
                rng = self._rngs[site] = np.random.default_rng(
                    (self.seed, zlib.crc32(site.encode())))
            fire = rng.random() < spec.prob
        if fire:
            self._fired[site] = self._fired.get(site, 0) + 1
            raise FaultInjected(
                f"injected {site} fault (check #{n} of site {site!r})")

    # -- introspection --------------------------------------------------------

    @property
    def fired(self) -> int:
        """Total faults raised so far, across all sites."""
        return sum(self._fired.values())

    def fired_by_site(self) -> dict[str, int]:
        return dict(self._fired)

    def checks(self, site: str) -> int:
        return self._counts.get(site, 0)

    def clear(self) -> None:
        """Drop all specs (keep counters): the 'injector removed' state."""
        self._specs.clear()


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_every: int = 100
    async_checkpoint: bool = True
    max_restarts: int = 5
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


@dataclasses.dataclass
class SupervisorStats:
    restarts: int = 0
    straggler_steps: int = 0
    checkpoints: int = 0
    ewma_step_s: float = 0.0


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, cfg: SupervisorConfig):
        self.ckpt = ckpt
        self.cfg = cfg
        self.stats = SupervisorStats()

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        num_steps: int,
        start_step: int = 0,
        state_shardings: Any = None,
    ) -> Any:
        """Run ``step_fn(state, i) -> state`` with restart-on-failure.

        On exception: restore latest checkpoint, resume from its step.
        """
        i = start_step
        restarts_left = self.cfg.max_restarts
        while i < num_steps:
            try:
                t0 = time.monotonic()
                state = step_fn(state, i)
                dt = time.monotonic() - t0
                st = self.stats
                if st.ewma_step_s == 0.0:
                    st.ewma_step_s = dt
                else:
                    a = self.cfg.ewma_alpha
                    if dt > self.cfg.straggler_factor * st.ewma_step_s:
                        st.straggler_steps += 1
                        log.warning(
                            "straggler step %d: %.3fs vs ewma %.3fs",
                            i, dt, st.ewma_step_s,
                        )
                    st.ewma_step_s = (1 - a) * st.ewma_step_s + a * dt
                i += 1
                if i % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(
                        i, state, blocking=not self.cfg.async_checkpoint)
                    self.stats.checkpoints += 1
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — restart-on-any-failure
                if restarts_left == 0:
                    raise RuntimeError(
                        f"supervisor: out of restarts at step {i}"
                    ) from e
                restarts_left -= 1
                self.stats.restarts += 1
                log.error("step %d failed (%s); restoring", i, e)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    log.error("no checkpoint to restore; restarting fresh")
                    i = start_step
                    continue
                state, i = self.ckpt.restore(
                    state, shardings=state_shardings)
        self.ckpt.wait()
        return state


class FailureInjector:
    """Deterministic failure injection for tests: raises at given steps."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.raised: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.raised:
            self.raised.add(step)
            raise RuntimeError(f"injected failure at step {step}")
