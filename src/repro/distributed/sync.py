"""``sync`` strategy — synchronous data-parallel minibatch STD.

TPU-native adaptation of the paper's multi-GPU scheme: every device samples
from its local shard of Ω, computes dense factor/core gradients, ``psum``
over the data axis, identical update everywhere. Exact, stateless, composes
with int8 error-feedback gradient compression (the EF residuals live
per-device, stacked on a leading device axis and sharded over the mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fasttucker import (
    FastTuckerConfig, FastTuckerParams, TrainState, _sgd_update,
    batch_layout, dynamic_lr, scatter_row_grads, step_gradients,
)
from repro.core.sampling import sample_batch_arrays
from repro.core.sptensor import SparseTensor

from .base import DistState, DistStrategy, compressed_reduce, step_donation


def shard_nonzeros(tensor: SparseTensor, num_shards: int):
    """Pad + split Ω round-robin into (num_shards, L, ·) arrays.

    Padding TILES Ω (index arithmetic mod nnz), so ``nnz < num_shards``
    — where the old ``indices[:pad]`` slice came up short and broke the
    reshape — pads correctly by wrapping around.
    """
    nnz = tensor.nnz
    L = -(-nnz // num_shards)
    sel = jnp.arange(L * num_shards) % nnz
    return (tensor.indices[sel].reshape(num_shards, L, -1),
            tensor.values[sel].reshape(num_shards, L))


def init_error_feedback(params: FastTuckerParams):
    """Zero EF residuals, factor-shaped (legacy replicated layout)."""
    return tuple(jnp.zeros_like(f) for f in params.factors)


def _sync_local_update(cfg: FastTuckerConfig, axis: str, compress: bool,
                       params, step_no, key, idx_shard, val_shard, ef):
    """Per-device body shared by the legacy step and the strategy step.

    ``ef`` is a tuple of per-device factor-shaped residuals (already
    unstacked); returns (new_params, new_ef).
    """
    me = jax.lax.axis_index(axis)
    key = jax.random.fold_in(key, me)
    idx, val = sample_batch_arrays(key, idx_shard, val_shard, cfg.batch_size)
    layout = batch_layout(idx, cfg)  # per-device mode-sorted view
    grads = step_gradients(params, idx, val, cfg, layout=layout)
    dense = scatter_row_grads(params.factors, idx, grads.row_grads,
                              backend=cfg.backend, layout=layout)
    if compress:
        dense, ef = compressed_reduce(dense, ef, axis)
    else:
        dense = jax.lax.psum(dense, axis)
    core = jax.lax.psum(grads.core_grads, axis)
    nshards = jax.lax.psum(1, axis)
    lr_a = dynamic_lr(cfg.alpha_a, cfg.beta_a, step_no)
    lr_b = dynamic_lr(cfg.alpha_b, cfg.beta_b, step_no)
    factors = tuple(
        _sgd_update(f, lr_a / nshards, g)
        for f, g in zip(params.factors, dense))
    core_f = tuple(
        _sgd_update(b, lr_b / nshards, g)
        for b, g in zip(params.core_factors, core))
    return FastTuckerParams(factors, core_f), ef


def make_sync_step(cfg: FastTuckerConfig, mesh: Mesh, axis: str = "data",
                   compress: bool = False):
    """Legacy entry point: jit'd step(params, step_no, key, idx, val, ef).

    Kept for existing call sites; new code should drive ``SyncStrategy``
    through the registry (its EF residuals are properly device-sharded
    instead of replicated-with-divergence).
    """
    from jax.experimental.shard_map import shard_map

    def local_step(params, step_no, key, idx_shard, val_shard, ef):
        # shard_map blocks keep a size-1 leading dim — drop it
        new_params, new_ef = _sync_local_update(
            cfg, axis, compress,
            params, step_no, key, idx_shard[0], val_shard[0], ef)
        return new_params, new_ef

    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# strategy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SyncPlan:
    cfg: FastTuckerConfig
    mesh: Mesh
    idx_shards: jax.Array   # (M, L, N)
    val_shards: jax.Array   # (M, L)
    compress: bool
    axis: str = "data"

    @property
    def num_devices(self) -> int:
        return self.idx_shards.shape[0]


def _build_jitted(plan: SyncPlan):
    from jax.experimental.shard_map import shard_map

    cfg, axis = plan.cfg, plan.axis

    def local_step(dstate: DistState, idx_shard, val_shard) -> DistState:
        step_key = jax.random.fold_in(dstate.key, dstate.step)
        # EF residuals arrive stacked (1, I_n, J_n) per device
        ef = tuple(e[0] for e in dstate.ef)
        new_params, new_ef = _sync_local_update(
            cfg, axis, plan.compress, dstate.params, dstate.step, step_key,
            idx_shard[0], val_shard[0], ef)
        new_ef = tuple(e[None] for e in new_ef)
        return DistState(new_params, dstate.step + 1, dstate.key, new_ef)

    ef_spec = tuple(P(axis) for _ in range(len(plan.cfg.dims))) \
        if plan.compress else ()
    state_spec = DistState(
        params=FastTuckerParams(
            tuple(P() for _ in plan.cfg.dims),
            tuple(P() for _ in plan.cfg.dims),
        ),
        step=P(), key=P(), ef=ef_spec,
    )
    sharded = shard_map(
        local_step,
        mesh=plan.mesh,
        in_specs=(state_spec, P(plan.axis), P(plan.axis)),
        out_specs=state_spec,
        check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=step_donation())


class SyncStrategy(DistStrategy):
    name = "sync"

    def prepare(self, tensor: SparseTensor, cfg: FastTuckerConfig, mesh,
                *, compress: bool = False, seed: int = 0) -> SyncPlan:
        idx_sh, val_sh = shard_nonzeros(tensor, mesh.devices.size)
        return SyncPlan(cfg, mesh, idx_sh, val_sh, compress)

    def init(self, plan: SyncPlan, state: TrainState,
             key: jax.Array) -> DistState:
        M = plan.num_devices
        acc = jnp.dtype(plan.cfg.accum_dtype)  # EF lives in grad dtype
        ef = (tuple(
            jnp.zeros((M,) + f.shape, acc) for f in state.params.factors)
            if plan.compress else ())
        return DistState(state.params, jnp.asarray(state.step, jnp.int32),
                         key, ef)

    def nnz_per_step(self, plan: SyncPlan) -> int:
        # every device samples its own |Ψ| from its Ω shard
        return plan.cfg.batch_size * plan.num_devices

    def make_step(self, plan: SyncPlan
                  ) -> Callable[[DistState], DistState]:
        jitted = _build_jitted(plan)
        return lambda dstate: jitted(dstate, plan.idx_shards,
                                     plan.val_shards)

    def lower_step(self, plan: SyncPlan, dstate: DistState):
        return _build_jitted(plan).lower(dstate, plan.idx_shards,
                                         plan.val_shards)
