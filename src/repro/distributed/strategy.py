"""DEPRECATED compatibility shim — the strategy layer moved to a registry.

This module used to hold the two hand-rolled multi-device STD modes. They
now live behind the named strategy registry (``repro.distributed``):

    from repro.distributed import get_strategy
    strategy = get_strategy("strata")          # or sync / strata_overlap

The old entry points are re-exported here unchanged so existing call sites
keep working:

    ``shard_nonzeros`` / ``make_sync_step`` / ``init_error_feedback``
        → ``repro.distributed.sync``
    ``StrataPlan`` (now ``StrataLayout``) / ``pad_factors_for_strata`` /
    ``make_strata_step``
        → ``repro.distributed.strata``
"""
from __future__ import annotations

from .strata import (                                         # noqa: F401
    StrataLayout as StrataPlan,
    make_strata_step,
    pad_factors_for_strata,
)
from .sync import (                                           # noqa: F401
    init_error_feedback,
    make_sync_step,
    shard_nonzeros,
)

__all__ = [
    "shard_nonzeros",
    "make_sync_step",
    "init_error_feedback",
    "StrataPlan",
    "pad_factors_for_strata",
    "make_strata_step",
]
