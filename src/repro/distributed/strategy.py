"""Multi-device STD strategies — the paper's §5.3 scheme on a JAX mesh.

Two modes:

``sync``  — synchronous minibatch (TPU-native adaptation): every device
            samples from its local shard of Ω, computes dense factor/core
            gradients, ``psum`` over the data axis, identical update
            everywhere. Exact, stateless, composes with gradient
            compression. Factors replicated per data shard.

``strata`` — the faithful cuFastTucker Fig. 2 analogue: factor matrices are
            ROW-SHARDED over M devices; each step draws one stratum s (a
            generalized diagonal of the M^N block grid), ``ppermute``-rotates
            each mode's factor shards by the stratum digit so that every
            device holds exactly the rows its bucket touches, updates
            locally (conflict-free by construction), and rotates back.
            Communication per step = 2·N shard rotations (point-to-point),
            independent of M — the property that made the paper's M-GPU
            scaling near-linear. Core factors B^(n) are small → replicated,
            gradient psum'd (paper: "accumulate all gradients then update").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fasttucker import (
    FastTuckerConfig, FastTuckerParams, batch_gradients, dynamic_lr,
    scatter_row_grads,
)
from repro.core.sampling import sample_batch_arrays
from repro.core.sptensor import SparseTensor, partition_for_workers
from repro.optim.compression import compress_ef, decompress


# ---------------------------------------------------------------------------
# sync mode
# ---------------------------------------------------------------------------

def shard_nonzeros(tensor: SparseTensor, num_shards: int):
    """Pad + split Ω round-robin into (num_shards, L, ·) arrays."""
    nnz = tensor.nnz
    L = -(-nnz // num_shards)
    pad = L * num_shards - nnz
    idx = jnp.concatenate([tensor.indices, tensor.indices[:pad]], 0)
    val = jnp.concatenate([tensor.values, tensor.values[:pad]], 0)
    return (idx.reshape(num_shards, L, -1), val.reshape(num_shards, L))


def make_sync_step(cfg: FastTuckerConfig, mesh: Mesh, axis: str = "data",
                   compress: bool = False):
    """Returns jit'd step(state, key, idx_shards, val_shards) — ``sync``."""

    def local_step(params, step_no, key, idx_shard, val_shard, ef):
        # shard_map blocks keep a size-1 leading dim — drop it
        idx_shard = idx_shard[0]
        val_shard = val_shard[0]
        me = jax.lax.axis_index(axis)
        key = jax.random.fold_in(key, me)
        idx, val = sample_batch_arrays(
            key, idx_shard, val_shard, cfg.batch_size)
        grads = batch_gradients(
            params, idx, val, cfg.lambda_a, cfg.lambda_b,
            backend=cfg.backend,
        )
        dense = scatter_row_grads(params.factors, idx, grads.row_grads,
                                  backend=cfg.backend)
        if compress:
            new_ef = []
            summed = []
            for g, e in zip(dense, ef):
                q, scale, new_e = compress_ef(g, e)
                deq = decompress(q, scale)
                summed.append(jax.lax.psum(deq, axis))
                new_ef.append(new_e)
            dense = tuple(summed)
            ef = tuple(new_ef)
        else:
            dense = jax.lax.psum(dense, axis)
        core = jax.lax.psum(grads.core_grads, axis)
        nshards = jax.lax.psum(1, axis)
        lr_a = dynamic_lr(cfg.alpha_a, cfg.beta_a, step_no)
        lr_b = dynamic_lr(cfg.alpha_b, cfg.beta_b, step_no)
        factors = tuple(
            f - (lr_a / nshards) * g for f, g in zip(params.factors, dense))
        core_f = tuple(
            b - (lr_b / nshards) * g
            for b, g in zip(params.core_factors, core))
        return FastTuckerParams(factors, core_f), ef

    from jax.experimental.shard_map import shard_map

    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(sharded)


def init_error_feedback(params: FastTuckerParams):
    return tuple(jnp.zeros_like(f) for f in params.factors)


# ---------------------------------------------------------------------------
# strata mode (faithful Fig. 2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StrataPlan:
    """Host-side prep for the stratified schedule."""
    buckets: dict          # from partition_for_workers
    rows_per_block: tuple  # per mode (padded row count / M)
    num_workers: int

    @classmethod
    def build(cls, tensor: SparseTensor, num_workers: int):
        M = num_workers
        padded_dims = tuple(-(-d // M) * M for d in tensor.dims)
        padded = SparseTensor(tensor.indices, tensor.values, padded_dims)
        buckets = partition_for_workers(padded, M)
        return cls(buckets, tuple(d // M for d in padded_dims), M)

    def stratum_digits(self, s: int) -> np.ndarray:
        """Base-M digits (mode 1..N-1 shifts) of stratum s."""
        N = self.buckets["indices"].shape[-1]
        out = np.zeros(N, dtype=np.int64)
        rem = s
        for n in range(1, N):
            out[n] = rem % self.num_workers
            rem //= self.num_workers
        return out


def pad_factors_for_strata(params: FastTuckerParams, plan: StrataPlan
                           ) -> FastTuckerParams:
    M = plan.num_workers
    factors = tuple(
        jnp.pad(f, ((0, plan.rows_per_block[n] * M - f.shape[0]), (0, 0)))
        for n, f in enumerate(params.factors)
    )
    return FastTuckerParams(factors, params.core_factors)


def make_strata_step(cfg: FastTuckerConfig, mesh: Mesh, plan: StrataPlan,
                     axis: str = "data"):
    """Step over ONE stratum: rotate shards in, local conflict-free update,
    rotate back. Factor rows sharded over `axis`; B^(n) replicated."""
    M = plan.num_workers
    N = cfg.order

    from jax.experimental.shard_map import shard_map

    # The stratum is host-chosen per step, so specialize the compiled step
    # per digit tuple: rotations become STATIC ppermutes (no lax.switch over
    # collectives, which deadlocks/blows up compile). At most M^(N-1)
    # variants exist; the jit cache holds the ones actually visited.
    @functools.lru_cache(maxsize=None)
    def _specialized(digits: tuple):
        def local_step(params, step_no, key, idx_b, val_b, mask_b):
            # params.factors[n]: (rows_per_block, J) local shard
            idx_b, val_b, mask_b = idx_b[0], val_b[0], mask_b[0]
            me = jax.lax.axis_index(axis)

            def rotate(f, shift, inverse=False):
                # want the shard owned by (me + shift): send mine to
                # (me − shift), then everyone holds the (me + shift) shard.
                if shift % M == 0:
                    return f
                sgn = 1 if inverse else -1
                perm = [(i, (i + sgn * shift) % M) for i in range(M)]
                return jax.lax.ppermute(f, axis, perm)

            rot = [rotate(params.factors[n], digits[n]) for n in range(N)]

            key = jax.random.fold_in(key, me)
            pick = jax.random.randint(key, (cfg.batch_size,), 0,
                                      idx_b.shape[0])
            idx = idx_b[pick]
            val = val_b[pick]
            msk = mask_b[pick]

            # localize rows: mode-n block digit here is (me + digits[n]) % M
            local_idx = []
            for n in range(N):
                digit = (me + digits[n]) % M
                local_idx.append(idx[:, n] - digit * plan.rows_per_block[n])
            lidx = jnp.stack(local_idx, axis=1)

            lparams = FastTuckerParams(tuple(rot), params.core_factors)
            grads = batch_gradients(
                lparams, lidx, val, cfg.lambda_a, cfg.lambda_b, mask=msk,
                backend=cfg.backend,
            )
            dense = scatter_row_grads(lparams.factors, lidx, grads.row_grads,
                                      backend=cfg.backend)
            lr_a = dynamic_lr(cfg.alpha_a, cfg.beta_a, step_no)
            lr_b = dynamic_lr(cfg.alpha_b, cfg.beta_b, step_no)
            new_rot = tuple(f - lr_a * g for f, g in zip(rot, dense))

            # core factors: psum'd gradient, applied identically everywhere
            core = jax.lax.psum(grads.core_grads, axis)
            core_f = tuple(
                b - (lr_b / M) * g for b, g in zip(params.core_factors, core))

            back = tuple(
                rotate(new_rot[n], digits[n], inverse=True) for n in range(N)
            )
            return FastTuckerParams(back, core_f)

        sharded = shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                FastTuckerParams(
                    tuple(P(axis, None) for _ in range(N)),
                    tuple(P() for _ in range(N)),
                ),
                P(), P(),
                P(axis), P(axis), P(axis),
            ),
            out_specs=FastTuckerParams(
                tuple(P(axis, None) for _ in range(N)),
                tuple(P() for _ in range(N)),
            ),
            check_rep=False,
        )
        return jax.jit(sharded)

    def step(params, step_no, key, stratum: int):
        digits = tuple(int(d) for d in plan.stratum_digits(int(stratum)))
        b = plan.buckets
        idx_s = b["indices"][stratum]     # (M, L, N)
        val_s = b["values"][stratum]
        msk_s = b["mask"][stratum]
        return _specialized(digits)(params, step_no, key, idx_s, val_s,
                                    msk_s)

    return step
