"""Logical-axis → mesh-axis rules and NamedSharding construction.

Each parameter/cache leaf carries logical axis names (see models.layers).
Rules map logical names to mesh axes; a rule is applied per-leaf only when
the dimension is divisible by the mesh-axis extent (otherwise that dim is
replicated) and no mesh axis is used twice in one PartitionSpec.

Two built-in policies:
  * ``tp``       — tensor parallelism only: heads/mlp/experts/vocab on
                   `model`; everything else replicated per data shard.
  * ``fsdp_tp``  — additionally shard the `embed` axis over `data`
                   (ZeRO-3/FSDP via GSPMD); optimizer moments inherit it,
                   which is what lets 67B+ models fit 16 GB chips.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


RULES_TP: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "cache_batch": ("pod", "data"),
}

RULES_FSDP_TP = dict(RULES_TP, embed=("data",))

# v2 (hillclimb #2): when kv_heads doesn't divide the model axis the KV
# cache would replicate 16× — fall back to sharding head_dim (contracting
# dim → GSPMD inserts a small psum per step) and shard the MLA latent dim.
RULES_FSDP_TP_V2 = dict(
    RULES_FSDP_TP,
    head_dim_kv=("model",),
    kv_lora=("model",),
)

# zero3 (hillclimb pair 3): drop tensor parallelism for dense-train cells —
# per-layer TP activation all-reduces (~10 GiB/layer on deepseek-67B)
# outweigh the FSDP weight gathers they replace. Params/moments shard over
# data (ZeRO-3); vocab stays on `model` (logits memory); the free `model`
# axis carries sequence-parallel activations.
RULES_ZERO3 = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": ("data",),
    "cache_batch": ("pod", "data"),
    "mlp": (),
    "heads": (),
    "kv_heads": (),
    "experts": ("model",),   # EP stays: expert FFNs would replicate
}

# zero3_dp: additionally run data-parallel over the `model` axis too
# (microbatch 1/chip at GB=256 on 16×16) — activations never need SP
# gathers; the only collectives left are ZeRO weight gathers + grad
# reductions.
RULES_ZERO3_DP = dict(RULES_ZERO3, batch=("pod", "data", "model"),
                      cache_batch=("pod", "data", "model"))

POLICIES = {"tp": RULES_TP, "fsdp_tp": RULES_FSDP_TP,
            "fsdp_tp_v2": RULES_FSDP_TP_V2, "zero3": RULES_ZERO3,
            "zero3_dp": RULES_ZERO3_DP}

BATCH_AXES_BY_POLICY = {
    "zero3_dp": ("pod", "data", "model"),
}


def spec_for(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str, tuple[str, ...]],
) -> P:
    """Build a PartitionSpec honoring divisibility + axis-uniqueness."""
    used: set[str] = set()
    entries: list[Any] = []
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, axes):
        assign: tuple[str, ...] = ()
        if name is not None and name in rules:
            cand = tuple(
                a for a in rules[name]
                if a in mesh_sizes and a not in used
            )
            total = int(np.prod([mesh_sizes[a] for a in cand])) if cand else 1
            if cand and dim % total == 0 and dim >= total:
                assign = cand
                used.update(cand)
        if len(assign) == 0:
            entries.append(None)
        elif len(assign) == 1:
            entries.append(assign[0])
        else:
            entries.append(assign)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for_tree(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    policy: str = "fsdp_tp",
) -> Any:
    """Tree of NamedShardings matching (axes, shapes)."""
    rules = POLICIES[policy]

    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )

    return jax.tree.map(
        lambda ax, sd: NamedSharding(mesh, spec_for(ax, sd.shape, mesh, rules)),
        axes_tree,
        shape_tree,
        is_leaf=is_axes_leaf,
    )


def batch_spec(mesh: Mesh, batch_size: int, extra_dims: int = 1,
               policy: str = "fsdp_tp") -> P:
    """Shard leading batch dim over the policy's batch axes when divisible."""
    wanted = BATCH_AXES_BY_POLICY.get(policy, ("pod", "data"))
    axes = tuple(a for a in mesh.axis_names if a in wanted)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod([mesh_sizes[a] for a in axes]))
    if batch_size % total != 0:
        return P(*([None] * (1 + extra_dims)))
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * extra_dims))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# Serving (repro.serve): two table layouts behind one TuckerServer API.
# ROW mode shards each per-mode Kruskal-product table C^(n) ∈ (I_n, R)
# over the data axis — the same layout the strata training flavors use
# for factor shards, so a trained sharded run hands its layout straight
# to the server.  BATCH mode replicates the tables and splits request
# batches over data instead (small-table / high-QPS deployments); the
# automatic choice between them lives in repro.serve.policy.
RULES_SERVE: dict[str, tuple[str, ...]] = {"serve_rows": ("data",)}


def serve_row_sharding(mesh: Mesh, shape: Sequence[int]) -> NamedSharding:
    """NamedSharding row-sharding a (rows, R) serving table over ``data``.

    Goes through ``spec_for`` so the usual divisibility guard applies —
    a table whose row count doesn't divide the axis is replicated rather
    than mis-sharded (the serve engine pads rows to the axis size first,
    so in practice the shard always binds).
    """
    return NamedSharding(
        mesh, spec_for(("serve_rows", None), shape, mesh, RULES_SERVE))


def serve_table_replication(mesh: Mesh) -> NamedSharding:
    """The batch-sharded serving layout for the C^(n) tables: every
    device holds a full replica; the request batch (not the table) is
    what splits over ``data``.  The complement of ``serve_row_sharding``
    — see ``repro.serve.policy`` for when each pays."""
    return replicated(mesh)


# Cache leaves use positional axis conventions (see launch.steps):
CACHE_AXES = {
    # attention caches ("head_dim_kv"/"kv_lora" only bind under *_v2 rules)
    "k": ("cache_batch", None, "kv_heads", "head_dim_kv"),
    "v": ("cache_batch", None, "kv_heads", "head_dim_kv"),
    "c_kv": ("cache_batch", None, "kv_lora"),
    "k_pe": ("cache_batch", None, None),
    # ssm caches
    "conv": ("cache_batch", None, "mlp"),
    "ssm": ("cache_batch", "heads", None, None),
    "C": ("cache_batch", "heads", None, None),
    "n": ("cache_batch", "heads", None),
    "m": ("cache_batch", "heads"),
    "c": ("cache_batch", "heads", None),
    "h": ("cache_batch", "heads", None),
}


def cache_axes_tree(cache: Any) -> Any:
    """Assign logical axes to a cache pytree by leaf key name.

    Scanned groups prepend a layer axis — detected by ndim mismatch and
    padded with a leading None.
    """
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, dict):
                    out[k] = walk(v)
                elif isinstance(v, (list, tuple)):
                    out[k] = type(v)(walk(e) for e in v)
                else:
                    ax = CACHE_AXES.get(k, None)
                    if ax is None:
                        out[k] = tuple([None] * v.ndim)
                    else:
                        pad = v.ndim - len(ax)
                        out[k] = tuple([None] * pad) + tuple(ax)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(e) for e in node)
        if node is None:
            return None
        return tuple([None] * node.ndim)

    return walk(cache)
