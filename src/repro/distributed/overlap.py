"""``strata_overlap`` strategy — Fig. 2's pipeline with hidden rotations.

Same stratified schedule and per-stratum math as ``strata``, fused over a
chunk of K consecutive schedule entries inside ONE jitted shard_map step,
with the factor-shard rotations double-buffered:

  * shards stay in rotated position between strata — moving from stratum
    digits d to d' costs one ppermute by (d' − d) mod M per mode instead of
    the rotate-back + rotate-in pair (≤ half the collective bytes of
    ``strata``, fewer when consecutive digits coincide and the rotation is
    skipped entirely);
  * stratum s+1's rotation is ISSUED immediately after stratum s's row
    update, BEFORE stratum s's core-factor psum/update and stratum s+1's
    sampling/gather — none of which depend on the rotated shards — so XLA's
    scheduler is free to run the collective-permutes concurrently with that
    compute (async collective-permute-start/done on TPU). This is the
    communication-hiding emphasis of cuFasterTucker, expressed at the HLO
    level; ``launch.hlo_analysis.overlap_stats`` measures the hidden-flops
    window in the compiled step.

The chunk's digit sequence is static per compiled variant (the schedule is
pre-sampled per run), so rotations stay static ppermutes; at most ⌈S/K⌉
variants compile and are reused every epoch. Trajectories are identical to
``strata`` under the same seed/schedule: same per-stratum sample keys
(``fold_in(base, global_step)``), same update expressions — only the
rotation bookkeeping differs, and rotations are pure data movement.

Phase-split / mixed precision / mode-sorted batches ride through
``stratum_row_update`` (shared with ``strata``):
``FastTuckerConfig(phase_split=True)`` routes each stratum's gradients
through the ``StepIntermediates``-cached two-phase kernels,
``dtype="bfloat16"`` shards/rotates bf16 factor rows — HALF the ppermute
bytes per rotation — while the gradient psum stays f32, and
``sorted_batches=True`` sorts each device's localized draw per mode
(dedup gather + ``segment_reduce`` scatter; block localization preserves
row order, so the sorted layout composes with the rotated shard
positions).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.fasttucker import FastTuckerParams

from .base import DistState, step_donation
from .strata import (
    StrataRunPlan, StrataStrategy, _prepare_run_plan, core_update,
    rotate_shard, strata_state_spec, stratum_row_update,
)

DEFAULT_CHUNK = 4


@dataclasses.dataclass
class OverlapPlan(StrataRunPlan):
    chunk: int = DEFAULT_CHUNK


def _build_chunk_specializer(plan: OverlapPlan):
    from jax.experimental.shard_map import shard_map

    cfg, layout, axis = plan.cfg, plan.layout, plan.axis
    M, N = layout.num_workers, cfg.order
    spec = strata_state_spec(cfg, axis, plan.compress)
    home = (0,) * N

    @functools.lru_cache(maxsize=None)
    def specialized(digit_seq: tuple):
        K = len(digit_seq)

        def local_chunk(dstate: DistState, idx_c, val_c, msk_c) -> DistState:
            # per-device blocks (1, K, L, ·) → (K, L, ·)
            idx_c, val_c, msk_c = idx_c[0], val_c[0], msk_c[0]
            rot = [rotate_shard(dstate.params.factors[n], digit_seq[0][n],
                                M, axis) for n in range(N)]
            core_f = dstate.params.core_factors
            ef = tuple(e[0] for e in dstate.ef)
            for k, digits in enumerate(digit_seq):
                step_no = dstate.step + k
                skey = jax.random.fold_in(dstate.key, step_no)
                new_rot, core_grads = stratum_row_update(
                    cfg, layout, axis, digits, rot, core_f,
                    idx_c[k], val_c[k], msk_c[k], step_no, skey)
                # double buffer: issue the rotation toward the NEXT stratum
                # (home after the last) right away; the core psum/update and
                # the next stratum's sampling/gather below don't touch the
                # rotated shards, so the permutes overlap that compute
                nxt = digit_seq[k + 1] if k + 1 < K else home
                rot = [
                    rotate_shard(new_rot[n], (nxt[n] - digits[n]) % M,
                                 M, axis)
                    for n in range(N)
                ]
                core_f, ef = core_update(cfg, axis, M, core_f, core_grads,
                                         ef, step_no, plan.compress)
            ef = tuple(e[None] for e in ef)
            return DistState(FastTuckerParams(tuple(rot), core_f),
                             dstate.step + K, dstate.key, ef)

        sharded = shard_map(
            local_chunk,
            mesh=plan.mesh,
            in_specs=(spec, P(axis), P(axis), P(axis)),
            out_specs=spec,
            check_rep=False,
        )
        return jax.jit(sharded, donate_argnums=step_donation())

    return specialized


class StrataOverlapStrategy(StrataStrategy):
    """Inherits ``init`` (padded factors + EF) and the row-trimming
    ``eval_params`` from ``StrataStrategy``; only the step changes."""

    name = "strata_overlap"

    def __init__(self, chunk: int = DEFAULT_CHUNK):
        self.chunk = chunk

    def prepare(self, tensor, cfg, mesh, *, compress: bool = False,
                seed: int = 0, store=None,
                prefetch_depth: int = 2) -> OverlapPlan:
        base = _prepare_run_plan(tensor, cfg, mesh, compress, seed,
                                 store=store, prefetch_depth=prefetch_depth)
        chunk = max(1, min(self.chunk, len(base.schedule)))
        return OverlapPlan(
            cfg=base.cfg, mesh=base.mesh, layout=base.layout,
            schedule=base.schedule, digits=base.digits,
            compress=base.compress, axis=base.axis, store=base.store,
            prefetch_depth=base.prefetch_depth, chunk=chunk)

    def steps_per_call(self, plan: OverlapPlan) -> int:
        return plan.chunk

    def nnz_per_step(self, plan: OverlapPlan) -> int:
        return plan.cfg.batch_size * plan.layout.num_workers

    def make_step(self, plan: OverlapPlan
                  ) -> Callable[[DistState], DistState]:
        specialized = _build_chunk_specializer(plan)
        S = len(plan.schedule)

        def digit_seq_at(pos: int):
            K = min(plan.chunk, S - pos)
            return tuple(
                tuple(int(d) for d in plan.digits[pos + k])
                for k in range(K)
            )

        if plan.store is not None:
            # out-of-core: the prefetcher walks K-stratum GROUPS (the
            # unit this strategy consumes), assembling each (M, K, L, ·)
            # block + issuing it to device ahead of the fused step —
            # host→device double buffering layered on top of the
            # rotation double buffering inside the compiled chunk
            fetch = _make_chunk_prefetcher(plan)

            def step(dstate: DistState) -> DistState:
                pos = int(dstate.step) % S
                idx_c, val_c, msk_c = fetch.take(pos)
                return specialized(digit_seq_at(pos))(
                    dstate, idx_c, val_c, msk_c)

            step.prefetcher = fetch
            return step

        chunk_for = _chunk_data_cache(plan)

        def step(dstate: DistState) -> DistState:
            pos = int(dstate.step) % len(plan.schedule)
            digit_seq, idx_c, val_c, msk_c = chunk_for(pos)
            return specialized(digit_seq)(dstate, idx_c, val_c, msk_c)

        return step

    def lower_step(self, plan: OverlapPlan, dstate: DistState):
        specialized = _build_chunk_specializer(plan)
        if plan.store is not None:
            K = min(plan.chunk, len(plan.schedule))
            digit_seq = tuple(
                tuple(int(d) for d in plan.digits[k]) for k in range(K))
            idx_c, val_c, msk_c = plan.store.strata_block(
                plan.schedule[:K])
        else:
            digit_seq, idx_c, val_c, msk_c = _chunk_data_cache(plan)(0)
        return specialized(digit_seq).lower(dstate, idx_c, val_c, msk_c)


def _make_chunk_prefetcher(plan: OverlapPlan):
    """Prefetcher over K-stratum schedule groups (device-major blocks)."""
    from repro.data.pipeline import StratumPrefetcher
    from repro.distributed.strata import _block_sharding

    store, S = plan.store, len(plan.schedule)
    sharding = _block_sharding(plan)

    def load(pos: int):
        K = min(plan.chunk, S - pos)
        return store.strata_block(plan.schedule[pos: pos + K])

    def next_pos(pos: int) -> int:
        return (pos + min(plan.chunk, S - pos)) % S

    return StratumPrefetcher(
        load, next_pos, depth=plan.prefetch_depth,
        place_fn=lambda blocks: jax.device_put(blocks, sharding),
    )


def _chunk_data_cache(plan: OverlapPlan):
    """Schedule position → (static digit sequence, device-major buckets).

    Bucket blocks are rearranged (K, M, L, ·) → (M, K, L, ·) so the mesh
    axis shards the leading dim. Memoized per position (≤ ⌈S/K⌉ entries on
    the aligned path; restores from a foreign step counter just start a
    shorter chunk at the next boundary).
    """
    b = plan.layout.buckets
    S = len(plan.schedule)

    @functools.lru_cache(maxsize=None)
    def chunk_for(pos: int):
        K = min(plan.chunk, S - pos)
        ids = np.asarray(plan.schedule[pos: pos + K])
        digit_seq = tuple(
            tuple(int(d) for d in plan.digits[pos + k])
            for k in range(K)
        )
        idx_c = jnp.swapaxes(b["indices"][ids], 0, 1)  # (M, K, L, N)
        val_c = jnp.swapaxes(b["values"][ids], 0, 1)   # (M, K, L)
        msk_c = jnp.swapaxes(b["mask"][ids], 0, 1)     # (M, K, L)
        return digit_seq, idx_c, val_c, msk_c

    return chunk_for
