"""``local`` strategy — single-device SGD through the uniform interface.

The reference trajectory the distributed strategies are tested against.
Mesh-free (``needs_mesh = False``). With ``compress=True`` the dense factor
gradients go through the same int8 error-feedback round-trip the
distributed strategies apply around their collectives (no reduction here),
making this the single-device numerics reference for compressed runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.fasttucker import (
    FastTuckerConfig, FastTuckerParams, TrainState, _sgd_update,
    batch_layout, dynamic_lr, scatter_row_grads, sgd_step, step_gradients,
)
from repro.core.sampling import sample_batch_arrays
from repro.core.sptensor import SparseTensor

from .base import DistState, DistStrategy, compressed_reduce, step_donation


@dataclasses.dataclass(frozen=True)
class LocalPlan:
    cfg: FastTuckerConfig
    indices: jax.Array
    values: jax.Array
    compress: bool


def _build_jitted(plan: LocalPlan):
    cfg = plan.cfg

    donate = step_donation()

    if not plan.compress:
        # uncompressed local IS the core trainer (both update orders and
        # the phase-split/dtype config live in sgd_step) — reuse it
        # rather than maintaining a parallel copy
        @partial(jax.jit, donate_argnums=donate)
        def core_step(dstate: DistState, indices, values) -> DistState:
            key = jax.random.fold_in(dstate.key, dstate.step)
            st = sgd_step(TrainState(dstate.params, dstate.step), key,
                          indices, values, cfg)
            return DistState(st.params, st.step, dstate.key, dstate.ef)

        return core_step

    @partial(jax.jit, donate_argnums=donate)
    def step(dstate: DistState, indices, values) -> DistState:
        key = jax.random.fold_in(dstate.key, dstate.step)
        idx, val = sample_batch_arrays(key, indices, values, cfg.batch_size)
        layout = batch_layout(idx, cfg)
        grads = step_gradients(dstate.params, idx, val, cfg, layout=layout)
        dense = scatter_row_grads(dstate.params.factors, idx,
                                  grads.row_grads, backend=cfg.backend,
                                  layout=layout)
        dense, ef = compressed_reduce(dense, dstate.ef, axis=None)
        lr_a = dynamic_lr(cfg.alpha_a, cfg.beta_a, dstate.step)
        lr_b = dynamic_lr(cfg.alpha_b, cfg.beta_b, dstate.step)
        factors = tuple(
            _sgd_update(f, lr_a, g)
            for f, g in zip(dstate.params.factors, dense))
        core = tuple(
            _sgd_update(b, lr_b, g)
            for b, g in zip(dstate.params.core_factors, grads.core_grads))
        return DistState(FastTuckerParams(factors, core),
                         dstate.step + 1, dstate.key, ef)

    return step


class LocalStrategy(DistStrategy):
    name = "local"
    needs_mesh = False

    def prepare(self, tensor: SparseTensor, cfg: FastTuckerConfig, mesh=None,
                *, compress: bool = False, seed: int = 0) -> LocalPlan:
        if compress and cfg.update_order == "gauss_seidel":
            raise ValueError(
                "local --compress is only defined for the jacobi update "
                "order (gauss_seidel updates modes sequentially; there is "
                "no single dense gradient to quantize)")
        return LocalPlan(cfg, tensor.indices, tensor.values, compress)

    def init(self, plan: LocalPlan, state: TrainState,
             key: jax.Array) -> DistState:
        # EF residuals live in the GRADIENT (accum) dtype — f32 even when
        # the factors are stored bf16
        acc = jnp.dtype(plan.cfg.accum_dtype)
        ef = (tuple(jnp.zeros(f.shape, acc) for f in state.params.factors)
              if plan.compress else ())
        return DistState(state.params, jnp.asarray(state.step, jnp.int32),
                         key, ef)

    def make_step(self, plan: LocalPlan
                  ) -> Callable[[DistState], DistState]:
        jitted = _build_jitted(plan)
        return lambda dstate: jitted(dstate, plan.indices, plan.values)

    def lower_step(self, plan: LocalPlan, dstate: DistState):
        return _build_jitted(plan).lower(dstate, plan.indices, plan.values)
