"""Named distributed-strategy registry — §5.3 data division behind one API.

The same pattern as the kernel-backend registry (``repro.kernels.dispatch``)
one layer up: every multi-device training scheme is a ``DistStrategy``
registered under a name:

    ``"local"``          single-device SGD (reference trajectory)
    ``"sync"``           synchronous data-parallel minibatch (psum'd grads)
    ``"strata"``         the paper's Fig.-2 stratified rotation, one stratum
                         per step over a pre-sampled Latin-hypercube epoch
                         schedule
    ``"strata_overlap"`` same schedule, fused over a chunk of strata with
                         the shard rotations double-buffered so stratum
                         s+1's ``ppermute`` is issued alongside stratum s's
                         remaining compute (communication hiding,
                         cuFasterTucker-style)

Uniform contract (the launcher drives every strategy through this):

    plan    = strategy.prepare(tensor, cfg, mesh, compress=..., seed=...)
    dstate  = strategy.init(plan, train_state, key)
    step_fn = strategy.make_step(plan)
    dstate  = step_fn(dstate)                   # advances steps_per_call
    params  = strategy.eval_params(plan, dstate)  # strata row-trim included
    strategy.save(plan, ckpt, dstate) / strategy.restore(plan, ckpt, dstate)

``DistState`` is one pytree — parameters, step counter, base PRNG key, and
error-feedback residuals — so checkpoint save/restore is identical across
strategies, and int8 error-feedback compression (``--compress``) works
under every strategy, not just ``sync``.

New strategies (hierarchical meshes, async parameter servers, …) register
via ``register_strategy`` without touching any call site.
"""
from __future__ import annotations

import abc
import os
import warnings
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fasttucker import FastTuckerConfig, FastTuckerParams, TrainState

ENV_VAR = "REPRO_DIST_STRATEGY"
DEFAULT_STRATEGY = "local"


class DistState(NamedTuple):
    """Uniform distributed training state (one checkpointable pytree).

    ``ef`` holds the int8 error-feedback residuals when compression is on
    (strategy-specific shapes: factor-shaped for local/sync, per-device
    core-factor-shaped for the strata flavors) and is ``()`` otherwise.
    """

    params: FastTuckerParams
    step: jax.Array            # int32 global update counter (strata count)
    key: jax.Array             # base PRNG key; per-step keys are fold_in'd
    ef: tuple = ()


class DistStrategy(abc.ABC):
    """Interface every distributed training scheme implements."""

    name: str = "?"
    needs_mesh: bool = True

    # -- lifecycle -----------------------------------------------------------

    @abc.abstractmethod
    def prepare(self, tensor, cfg: FastTuckerConfig, mesh, *,
                compress: bool = False, seed: int = 0) -> Any:
        """Host-side data layout + schedule; returns an opaque plan."""

    @abc.abstractmethod
    def init(self, plan, state: TrainState, key: jax.Array) -> DistState:
        """Lift a fresh single-device ``TrainState`` into strategy state."""

    @abc.abstractmethod
    def make_step(self, plan) -> Callable[[DistState], DistState]:
        """Build the update function (advances ``steps_per_call`` steps)."""

    def steps_per_call(self, plan) -> int:
        return 1

    def nnz_per_step(self, plan) -> int:
        """Nonzeros consumed per update step (throughput accounting).

        Default: one |Ψ| draw.  Strategies whose devices each draw their
        own |Ψ| (sync, the strata flavors) override with M·|Ψ|.
        """
        return plan.cfg.batch_size

    # -- evaluation ----------------------------------------------------------

    def eval_params(self, plan, dstate: DistState) -> FastTuckerParams:
        """Parameters in the global (unpadded, unrotated) layout.

        The strata flavors override this to trim padded factor rows — the
        trimming previously inlined at every eval site in ``std_train``.
        """
        return dstate.params

    # -- online refresh ------------------------------------------------------

    def _lift_eval_params(self, plan, dstate: DistState,
                          state: TrainState) -> DistState:
        """Lift refreshed global-layout params back into strategy state.

        The inverse of ``eval_params``'s view: the base (local/sync)
        layout IS the global layout, so only the step counter moves; the
        strata flavors override this to re-pad factor rows to the device
        multiple.  ``key``/``ef`` carry over unchanged — the refresh is
        factor-phase only, so core-factor EF residuals stay meaningful.
        """
        return DistState(state.params, jnp.asarray(state.step, jnp.int32),
                         dstate.key, dstate.ef)

    def refresh_steps(self, plan, dstate: DistState, indices, values,
                      num_steps: int) -> tuple[DistState, tuple]:
        """K bounded factor-phase SGD steps over a recent-nonzero window.

        The strategy-uniform face of ``core.fasttucker.refresh_steps``:
        evaluate to the global layout, catch the factors up on the window
        (core frozen — the step cost stays O(batch) and the dirty set
        stays row-bounded), and lift the result back into this strategy's
        at-rest layout.  Per-step keys fold the current step count into
        ``dstate.key``, so successive refresh windows draw fresh samples
        and a full-epoch retrain is never implied.

        Returns ``(dstate', dirty)`` — ``dirty[n]`` the sorted int32 row
        ids of mode ``n`` touched by the window, sized for
        ``TuckerServer.update_rows(n, dirty[n], factors[n][dirty[n]])``.
        """
        from repro.core.fasttucker import refresh_steps as _core_refresh

        params = self.eval_params(plan, dstate)
        state = TrainState(params, jnp.asarray(dstate.step, jnp.int32))
        key = jax.random.fold_in(dstate.key, int(dstate.step))
        state, dirty = _core_refresh(state, key, indices, values,
                                     plan.cfg, num_steps)
        return self._lift_eval_params(plan, dstate, state), dirty

    # -- introspection (benchmarks / tests) ----------------------------------

    def lower_step(self, plan, dstate: DistState):
        """``jax.stages.Lowered`` for one representative compiled step.

        Benchmarks analyze its HLO for per-step collective bytes and
        communication/compute overlap evidence.
        """
        raise NotImplementedError(f"{self.name} has no lowerable step")

    # -- checkpointing (uniform across strategies) ---------------------------

    def save(self, plan, ckpt, dstate: DistState,
             blocking: bool = True) -> None:
        ckpt.save(int(dstate.step), dstate, blocking=blocking)

    def restore(self, plan, ckpt, like: DistState,
                step: int | None = None) -> DistState:
        restored, _ = ckpt.restore(like, step)
        return DistState(
            params=restored.params,
            step=jnp.asarray(restored.step, jnp.int32),
            key=restored.key,
            ef=restored.ef,
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, DistStrategy] = {}


def register_strategy(strategy: DistStrategy, *,
                      overwrite: bool = False) -> None:
    if strategy.name in _REGISTRY and not overwrite:
        raise ValueError(f"strategy {strategy.name!r} already registered")
    _REGISTRY[strategy.name] = strategy


def available_strategies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_strategy_name(name: str | None = None,
                          mode: str | None = None) -> str:
    """explicit ``name`` > deprecated ``mode`` > $REPRO_DIST_STRATEGY > local.

    ``mode`` is the pre-registry ``--mode`` flag; passing it warns (same
    treatment as the kernel registry gave ``--use-kernel``).
    """
    if name:
        return name
    if mode:
        warnings.warn(
            "--mode is deprecated; use --strategy "
            f"{'/'.join(available_strategies())}",
            DeprecationWarning, stacklevel=2,
        )
        return mode
    return os.environ.get(ENV_VAR) or DEFAULT_STRATEGY


def get_strategy(name: str | None = None,
                 mode: str | None = None) -> DistStrategy:
    resolved = resolve_strategy_name(name, mode)
    try:
        return _REGISTRY[resolved]
    except KeyError:
        raise KeyError(
            f"unknown distributed strategy {resolved!r}; "
            f"available: {available_strategies()}"
        ) from None


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

DONATE_ENV_VAR = "REPRO_DONATE_STEP"


def step_donation() -> tuple[int, ...]:
    """``donate_argnums`` for the per-step jits (the DistState argument).

    Every strategy's compiled step is state → state with matching
    shapes/shardings, so donating the input state lets XLA reuse (alias)
    the parameter and EF buffers instead of allocating a fresh copy per
    step.  ``$REPRO_DONATE_STEP`` = ``on`` / ``off`` forces it; the
    default (``auto``) donates only off-CPU — CPU XLA cannot donate and
    would warn on every call.  Callers must rebind (``dstate =
    step(dstate)``), which the launcher and strategies already do.
    """
    mode = os.environ.get(DONATE_ENV_VAR, "auto").lower()
    if mode == "on":
        return (0,)
    if mode == "off":
        return ()
    return (0,) if jax.default_backend() != "cpu" else ()


def compressed_reduce(dense, ef, axis: str | None):
    """int8 error-feedback quantize → (psum over ``axis``) → dequantize.

    ``dense``/``ef`` are matching tuples of arrays. With ``axis=None`` the
    reduction is skipped (single-device: the quantization round-trip and
    residual carry still apply, so ``local --compress`` is the numerics
    reference for the distributed compressed paths).
    """
    from repro.optim.compression import compress_ef, decompress

    out, new_ef = [], []
    for g, e in zip(dense, ef):
        q, scale, ne = compress_ef(g, e)
        deq = decompress(q, scale)
        if axis is not None:
            deq = jax.lax.psum(deq, axis)
        out.append(deq)
        new_ef.append(ne)
    return tuple(out), tuple(new_ef)


__all__ = [
    "ENV_VAR",
    "DEFAULT_STRATEGY",
    "DONATE_ENV_VAR",
    "DistState",
    "DistStrategy",
    "register_strategy",
    "available_strategies",
    "resolve_strategy_name",
    "get_strategy",
    "compressed_reduce",
    "step_donation",
]
