"""Ambient activation-sharding context.

Model code is mesh-agnostic; the launcher can install a constraint applied
to the residual stream at block boundaries (Megatron-style sequence
parallelism: saved activations shard over the `model` axis, cutting
remat-saved bytes by the TP degree). Default: no-op.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

_CONSTRAIN: Optional[Callable] = None
_CONSTRAIN_LOGITS: Optional[Callable] = None
_MESH = None


def set_mesh(mesh) -> None:
    """Install the active mesh for manual-sharding islands (MoE)."""
    global _MESH
    _MESH = mesh


def current_mesh():
    return _MESH


def set_activation_constraint(fn: Optional[Callable]) -> None:
    global _CONSTRAIN
    _CONSTRAIN = fn


def constrain(x: jax.Array) -> jax.Array:
    if _CONSTRAIN is None:
        return x
    return _CONSTRAIN(x)


def set_logits_constraint(fn: Optional[Callable]) -> None:
    global _CONSTRAIN_LOGITS
    _CONSTRAIN_LOGITS = fn


def constrain_logits(x: jax.Array) -> jax.Array:
    if _CONSTRAIN_LOGITS is None:
        return x
    return _CONSTRAIN_LOGITS(x)


def make_logits_constraint(mesh, batch: int, vocab: int):
    """Shard (B, S, V) logits: batch→(pod,data), vocab→model."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    bsize = int(np.prod([sizes[a] for a in baxes]))
    bspec = (baxes if len(baxes) > 1 else baxes[0]) if batch % bsize == 0 \
        else None
    vspec = "model" if vocab % sizes.get("model", 1) == 0 else None
    sharding = NamedSharding(mesh, P(bspec, None, vspec))

    def fn(x):
        if x.ndim == 3 and x.shape[0] == batch and x.shape[-1] == vocab:
            return jax.lax.with_sharding_constraint(x, sharding)
        return x

    return fn


def make_seq_constraint(mesh, batch: int, seq: int, policy: str = "fsdp_tp"):
    """Shard (B, S, D) activations: batch→(pod,data), seq→model (if divisible)."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    bsize = int(np.prod([sizes[a] for a in baxes]))
    bspec = (baxes if len(baxes) > 1 else baxes[0]) if batch % bsize == 0 \
        else None
    sspec = "model" if seq % sizes.get("model", 1) == 0 else None
    spec = P(bspec, sspec)
    sharding = NamedSharding(mesh, spec)

    def fn(x):
        if x.ndim == 3 and x.shape[0] == batch and x.shape[1] == seq:
            return jax.lax.with_sharding_constraint(x, sharding)
        return x

    return fn
