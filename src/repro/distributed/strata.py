"""``strata`` strategy — the faithful cuFastTucker Fig. 2 analogue.

Factor matrices are ROW-SHARDED over M devices; each step handles one
stratum s (a generalized diagonal of the M^N block grid): ``ppermute``
rotates each mode's factor shards by the stratum digit so that every device
holds exactly the rows its bucket touches, updates locally (conflict-free
by construction), and rotates back. Communication per step = 2·N shard
rotations (point-to-point), independent of M — the property behind the
paper's near-linear M-GPU scaling. Core factors B^(n) are small →
replicated, gradient psum'd (optionally int8 error-feedback compressed:
that psum is the only gradient collective this strategy has).

Strata are visited in a pre-sampled Latin-hypercube epoch schedule
(``core.sampling.latin_hypercube_schedule``): every stratum — hence every
block — exactly once per epoch, replacing the old i.i.d. host draws which
left ~1/e of the blocks unvisited per S draws. The schedule is fixed per
run (seeded), so each stratum's rotations compile to STATIC ppermutes; at
most S specialized step variants exist and the jit cache holds them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fasttucker import (
    FastTuckerConfig, FastTuckerParams, TrainState, _sgd_update,
    batch_layout, dynamic_lr, scatter_row_grads, step_gradients,
)
from repro.core.sptensor import SparseTensor, partition_for_workers

from .base import DistState, DistStrategy, compressed_reduce, step_donation


# ---------------------------------------------------------------------------
# layout: buckets + padded row blocks (was ``StrataPlan`` pre-registry)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StrataLayout:
    """Host-side prep for the stratified schedule.

    Backed either by resident device buckets (``buckets``, from
    ``partition_for_workers``) or by an out-of-core ``NonzeroStore``
    (``store``) whose chunks have the identical (S, M, L, ·) layout —
    the per-stratum math never sees the difference.
    """
    buckets: dict | None   # from partition_for_workers (resident path)
    rows_per_block: tuple  # per mode (padded row count / M)
    num_workers: int
    store: "NonzeroStore | None" = None

    @classmethod
    def build(cls, tensor: SparseTensor, num_workers: int):
        M = num_workers
        padded_dims = tuple(-(-d // M) * M for d in tensor.dims)
        padded = SparseTensor(tensor.indices, tensor.values, padded_dims)
        buckets = partition_for_workers(padded, M)
        return cls(buckets, tuple(d // M for d in padded_dims), M)

    @classmethod
    def from_store(cls, store: "NonzeroStore"):
        """Out-of-core layout: chunks stay host-side in the store."""
        M = store.num_workers
        return cls(None, tuple(d // M for d in store.padded_dims), M,
                   store=store)

    @property
    def num_strata(self) -> int:
        if self.store is not None:
            return self.store.num_strata
        return self.buckets["indices"].shape[0]

    @property
    def order(self) -> int:
        if self.store is not None:
            return self.store.order
        return self.buckets["indices"].shape[-1]

    def stratum_digits(self, s: int) -> np.ndarray:
        """Base-M digits (mode 1..N-1 shifts) of stratum s."""
        from repro.core.sampling import stratum_digits

        return np.asarray(
            stratum_digits(jnp.asarray([s]), self.num_workers,
                           self.order))[0]


def pad_factors_for_strata(params: FastTuckerParams, plan: StrataLayout
                           ) -> FastTuckerParams:
    M = plan.num_workers
    factors = tuple(
        jnp.pad(f, ((0, plan.rows_per_block[n] * M - f.shape[0]), (0, 0)))
        for n, f in enumerate(params.factors)
    )
    return FastTuckerParams(factors, params.core_factors)


# ---------------------------------------------------------------------------
# per-stratum body (shared with ``strata_overlap``)
# ---------------------------------------------------------------------------

def rotate_shard(f: jax.Array, shift: int, M: int, axis: str) -> jax.Array:
    """Rotate row shards so each device ends up holding the block owned by
    (me + shift): send mine to (me − shift). Shifts COMPOSE additively, so
    moving from stratum digits d to d' is a rotation by (d' − d) mod M and
    returning home is a rotation by (−d) mod M."""
    if shift % M == 0:
        return f
    perm = [(i, (i - shift) % M) for i in range(M)]
    return jax.lax.ppermute(f, axis, perm)


def stratum_row_update(cfg: FastTuckerConfig, layout: StrataLayout,
                       axis: str, digits: tuple, rot, core_f,
                       idx_b, val_b, msk_b, step_no, key):
    """One stratum's conflict-free local row update, shards pre-rotated.

    ``rot`` holds each mode's factor shard rotated into ``digits`` position
    (device me owns rows block (me + digits[n]) of mode n). Samples |Ψ|
    nonzeros from this device's bucket, localizes indices, runs the fused
    gradient kernel, and applies the row update. The core-factor gradient
    psum/update is left to the caller so it can be ordered AFTER the next
    rotation is issued (communication hiding).

    Returns (updated rotated shards, per-device core gradients).
    """
    M = layout.num_workers
    me = jax.lax.axis_index(axis)
    key = jax.random.fold_in(key, me)
    pick = jax.random.randint(key, (cfg.batch_size,), 0, idx_b.shape[0])
    idx = idx_b[pick]
    val = val_b[pick]
    msk = msk_b[pick]

    # localize rows: mode-n block digit here is (me + digits[n]) % M
    local_idx = []
    for n in range(cfg.order):
        digit = (me + digits[n]) % M
        local_idx.append(idx[:, n] - digit * layout.rows_per_block[n])
    lidx = jnp.stack(local_idx, axis=1)

    # mode-sorted view of this device's draw: localization subtracts a
    # per-mode constant, so sorting the LOCAL ids is the same order the
    # global rows have — the layout composes with the rotated block
    # positions unchanged (masked padding entries may localize negative;
    # both scatter paths drop out-of-range rows identically)
    blayout = batch_layout(lidx, cfg)
    lparams = FastTuckerParams(tuple(rot), core_f)
    grads = step_gradients(lparams, lidx, val, cfg, mask=msk,
                           layout=blayout)
    dense = scatter_row_grads(lparams.factors, lidx, grads.row_grads,
                              backend=cfg.backend, layout=blayout)
    lr_a = dynamic_lr(cfg.alpha_a, cfg.beta_a, step_no)
    new_rot = tuple(_sgd_update(f, lr_a, g) for f, g in zip(rot, dense))
    return new_rot, grads.core_grads


def core_update(cfg: FastTuckerConfig, axis: str, M: int, core_f,
                core_grads, ef, step_no, compress: bool):
    """psum'd (optionally int8-EF-compressed) core-factor update."""
    if compress:
        summed, ef = compressed_reduce(core_grads, ef, axis)
    else:
        summed = jax.lax.psum(core_grads, axis)
    lr_b = dynamic_lr(cfg.alpha_b, cfg.beta_b, step_no)
    core_f = tuple(
        _sgd_update(b, lr_b / M, g) for b, g in zip(core_f, summed))
    return core_f, ef


def strata_state_spec(cfg: FastTuckerConfig, axis: str, compress: bool
                      ) -> DistState:
    """shard_map spec: factor rows sharded, core replicated, EF stacked."""
    N = cfg.order
    ef_spec = tuple(P(axis) for _ in range(N)) if compress else ()
    return DistState(
        params=FastTuckerParams(
            tuple(P(axis, None) for _ in range(N)),
            tuple(P() for _ in range(N)),
        ),
        step=P(), key=P(), ef=ef_spec,
    )


# ---------------------------------------------------------------------------
# legacy entry point (pre-registry API, kept for existing call sites)
# ---------------------------------------------------------------------------

def make_strata_step(cfg: FastTuckerConfig, mesh: Mesh, plan: StrataLayout,
                     axis: str = "data"):
    """Step over ONE stratum: rotate shards in, local conflict-free update,
    rotate back. Factor rows sharded over `axis`; B^(n) replicated."""
    M = plan.num_workers
    N = cfg.order

    from jax.experimental.shard_map import shard_map

    # The stratum is host-chosen per step, so specialize the compiled step
    # per digit tuple: rotations become STATIC ppermutes (no lax.switch over
    # collectives, which deadlocks/blows up compile). At most M^(N-1)
    # variants exist; the jit cache holds the ones actually visited.
    @functools.lru_cache(maxsize=None)
    def _specialized(digits: tuple):
        def local_step(params, step_no, key, idx_b, val_b, mask_b):
            idx_b, val_b, mask_b = idx_b[0], val_b[0], mask_b[0]
            rot = [rotate_shard(params.factors[n], digits[n], M, axis)
                   for n in range(N)]
            new_rot, core_grads = stratum_row_update(
                cfg, plan, axis, digits, rot, params.core_factors,
                idx_b, val_b, mask_b, step_no, key)
            back = tuple(
                rotate_shard(new_rot[n], -digits[n], M, axis)
                for n in range(N)
            )
            core_f, _ = core_update(cfg, axis, M, params.core_factors,
                                    core_grads, (), step_no, compress=False)
            return FastTuckerParams(back, core_f)

        sharded = shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                FastTuckerParams(
                    tuple(P(axis, None) for _ in range(N)),
                    tuple(P() for _ in range(N)),
                ),
                P(), P(),
                P(axis), P(axis), P(axis),
            ),
            out_specs=FastTuckerParams(
                tuple(P(axis, None) for _ in range(N)),
                tuple(P() for _ in range(N)),
            ),
            check_rep=False,
        )
        return jax.jit(sharded)

    def step(params, step_no, key, stratum: int):
        digits = tuple(int(d) for d in plan.stratum_digits(int(stratum)))
        b = plan.buckets
        idx_s = b["indices"][stratum]     # (M, L, N)
        val_s = b["values"][stratum]
        msk_s = b["mask"][stratum]
        return _specialized(digits)(params, step_no, key, idx_s, val_s,
                                    msk_s)

    return step


# ---------------------------------------------------------------------------
# strategy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StrataRunPlan:
    cfg: FastTuckerConfig
    mesh: Mesh
    layout: StrataLayout
    schedule: np.ndarray   # (S,) stratum ids — LHC epoch cover, fixed per run
    digits: np.ndarray     # (S, N) matching digits
    compress: bool
    axis: str = "data"
    store: "NonzeroStore | None" = None   # out-of-core chunk source
    prefetch_depth: int = 2               # device blocks issued ahead


def _prepare_run_plan(tensor, cfg, mesh, compress, seed, axis="data",
                      store=None, prefetch_depth=2):
    from repro.core.sampling import latin_hypercube_schedule, stratum_digits

    if store is not None:
        if store.num_workers != mesh.devices.size:
            raise ValueError(
                f"store was sharded for {store.num_workers} workers but "
                f"the mesh has {mesh.devices.size} devices — rebuild it "
                f"with NonzeroStore.build(tensor, {mesh.devices.size})")
        layout = StrataLayout.from_store(store)
    else:
        layout = StrataLayout.build(tensor, mesh.devices.size)
    M = layout.num_workers
    schedule = np.asarray(latin_hypercube_schedule(
        jax.random.PRNGKey(seed), M, cfg.order))
    digits = np.asarray(stratum_digits(schedule, M, cfg.order))
    return StrataRunPlan(cfg, mesh, layout, schedule, digits, compress,
                         axis, store, prefetch_depth)


def _block_sharding(plan: StrataRunPlan):
    """Devices-major placement for (M, …) schedule blocks: each device
    receives its own bucket slice during the prefetch, not at step time."""
    from jax.sharding import NamedSharding

    return NamedSharding(plan.mesh, P(plan.axis))


def make_stratum_prefetcher(plan: StrataRunPlan):
    """Prefetcher over the LHC schedule, one stratum per step.

    ``take(pos)`` yields the (idx, val, msk) device blocks for schedule
    position ``pos`` — loaded from the store and ``device_put`` on the
    prefetch thread ``plan.prefetch_depth`` strata ahead of consumption.
    """
    from repro.data.pipeline import StratumPrefetcher

    store, S = plan.store, len(plan.schedule)
    sharding = _block_sharding(plan)
    return StratumPrefetcher(
        lambda pos: store.stratum(int(plan.schedule[pos % S])),
        lambda pos: (pos + 1) % S,
        depth=plan.prefetch_depth,
        place_fn=lambda blocks: jax.device_put(blocks, sharding),
    )


def _init_strata_state(plan, state: TrainState, key) -> DistState:
    params = pad_factors_for_strata(state.params, plan.layout)
    M = plan.layout.num_workers
    acc = jnp.dtype(plan.cfg.accum_dtype)  # EF lives in grad dtype
    ef = (tuple(
        jnp.zeros((M,) + b.shape, acc)
        for b in state.params.core_factors)
        if plan.compress else ())
    return DistState(params, jnp.asarray(state.step, jnp.int32), key, ef)


def _build_strata_specializer(plan: StrataRunPlan):
    from jax.experimental.shard_map import shard_map

    cfg, layout, axis = plan.cfg, plan.layout, plan.axis
    M, N = layout.num_workers, cfg.order
    spec = strata_state_spec(cfg, axis, plan.compress)

    @functools.lru_cache(maxsize=None)
    def specialized(digits: tuple):
        def local_step(dstate: DistState, idx_b, val_b, msk_b) -> DistState:
            idx_b, val_b, msk_b = idx_b[0], val_b[0], msk_b[0]
            skey = jax.random.fold_in(dstate.key, dstate.step)
            rot = [rotate_shard(dstate.params.factors[n], digits[n], M, axis)
                   for n in range(N)]
            new_rot, core_grads = stratum_row_update(
                cfg, layout, axis, digits, rot, dstate.params.core_factors,
                idx_b, val_b, msk_b, dstate.step, skey)
            # issue the home rotation before the core psum/update: the two
            # have no data dependence, so the permutes can overlap it
            back = tuple(
                rotate_shard(new_rot[n], -digits[n], M, axis)
                for n in range(N)
            )
            ef = tuple(e[0] for e in dstate.ef)
            core_f, ef = core_update(
                cfg, axis, M, dstate.params.core_factors, core_grads, ef,
                dstate.step, plan.compress)
            ef = tuple(e[None] for e in ef)
            return DistState(FastTuckerParams(back, core_f),
                             dstate.step + 1, dstate.key, ef)

        sharded = shard_map(
            local_step,
            mesh=plan.mesh,
            in_specs=(spec, P(axis), P(axis), P(axis)),
            out_specs=spec,
            check_rep=False,
        )
        return jax.jit(sharded, donate_argnums=step_donation())

    return specialized


class StrataStrategy(DistStrategy):
    name = "strata"

    def prepare(self, tensor: SparseTensor, cfg: FastTuckerConfig, mesh,
                *, compress: bool = False, seed: int = 0,
                store=None, prefetch_depth: int = 2) -> StrataRunPlan:
        return _prepare_run_plan(tensor, cfg, mesh, compress, seed,
                                 store=store, prefetch_depth=prefetch_depth)

    def init(self, plan: StrataRunPlan, state: TrainState,
             key: jax.Array) -> DistState:
        return _init_strata_state(plan, state, key)

    def nnz_per_step(self, plan: StrataRunPlan) -> int:
        # every device draws |Ψ| nonzeros from its stratum bucket
        return plan.cfg.batch_size * plan.layout.num_workers

    def make_step(self, plan: StrataRunPlan
                  ) -> Callable[[DistState], DistState]:
        specialized = _build_strata_specializer(plan)
        S = len(plan.schedule)

        if plan.store is not None:
            # out-of-core: consume device blocks from the prefetcher —
            # stratum pos+depth is in flight while pos computes. The
            # blocks are bit-identical to the resident bucket slices
            # (the store writer mirrors partition_for_workers), so the
            # trajectory is too.
            fetch = make_stratum_prefetcher(plan)

            def step(dstate: DistState) -> DistState:
                pos = int(dstate.step) % S
                digits = tuple(int(d) for d in plan.digits[pos])
                idx_s, val_s, msk_s = fetch.take(pos)
                return specialized(digits)(dstate, idx_s, val_s, msk_s)

            step.prefetcher = fetch  # tests/benchmarks can close() it
            return step

        b = plan.layout.buckets

        @functools.lru_cache(maxsize=None)
        def bucket_for(s: int):
            # memoize the per-stratum device slices: the same S strata
            # repeat every epoch, no need to re-slice on the hot loop
            return b["indices"][s], b["values"][s], b["mask"][s]

        def step(dstate: DistState) -> DistState:
            pos = int(dstate.step) % S
            digits = tuple(int(d) for d in plan.digits[pos])
            idx_s, val_s, msk_s = bucket_for(int(plan.schedule[pos]))
            return specialized(digits)(dstate, idx_s, val_s, msk_s)

        return step

    def eval_params(self, plan: StrataRunPlan,
                    dstate: DistState) -> FastTuckerParams:
        return FastTuckerParams(
            tuple(f[: plan.cfg.dims[n]]
                  for n, f in enumerate(dstate.params.factors)),
            dstate.params.core_factors,
        )

    def _lift_eval_params(self, plan: StrataRunPlan, dstate: DistState,
                          state: TrainState) -> DistState:
        # re-pad the refreshed global-layout factors to the device-multiple
        # row counts the strata shard_map steps expect at rest (the next
        # step's in_specs re-place them on the mesh, as init does)
        return DistState(
            pad_factors_for_strata(state.params, plan.layout),
            jnp.asarray(state.step, jnp.int32), dstate.key, dstate.ef)

    def lower_step(self, plan: StrataRunPlan, dstate: DistState):
        specialized = _build_strata_specializer(plan)
        s = int(plan.schedule[0])
        digits = tuple(int(d) for d in plan.digits[0])
        if plan.store is not None:
            idx_s, val_s, msk_s = plan.store.stratum(s)
        else:
            b = plan.layout.buckets
            idx_s, val_s, msk_s = (b["indices"][s], b["values"][s],
                                   b["mask"][s])
        return specialized(digits).lower(dstate, idx_s, val_s, msk_s)
