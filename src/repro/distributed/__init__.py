"""Distributed layer: sharding rules, mesh context, and the §5.3 strategy
registry.

``get_strategy("local" | "sync" | "strata" | "strata_overlap")`` returns a
``DistStrategy`` — the uniform prepare/init/step/eval_params/save/restore
interface every launcher, example, and benchmark drives. See ``base`` for
the contract, ``strata``/``overlap`` for the paper's Fig.-2 scheme and its
communication-hiding variant.
"""
from .base import (
    DistState,
    DistStrategy,
    available_strategies,
    get_strategy,
    register_strategy,
    resolve_strategy_name,
    step_donation,
)
from .local import LocalStrategy
from .overlap import StrataOverlapStrategy
from .strata import StrataStrategy
from .sync import SyncStrategy

register_strategy(LocalStrategy())
register_strategy(SyncStrategy())
register_strategy(StrataStrategy())
register_strategy(StrataOverlapStrategy())

__all__ = [
    "DistState",
    "DistStrategy",
    "available_strategies",
    "get_strategy",
    "register_strategy",
    "resolve_strategy_name",
    "step_donation",
    "LocalStrategy",
    "SyncStrategy",
    "StrataStrategy",
    "StrataOverlapStrategy",
]
