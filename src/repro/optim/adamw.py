"""AdamW (functional, optax-free) with optional ZeRO-style sharded moments.

Moments reuse each parameter's logical axes, so under the ``fsdp_tp`` policy
they shard over (data × model) — the distributed-optimizer memory trick that
lets 67B-parameter training states fit 16 GB/chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.minimum(warm, 1.0) * cos


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def update(
    grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig
) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 1:  # decoupled weight decay (skip scalars/norm scales?)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
