"""Gradient compression: int8 quantization with error feedback.

Used around the data-parallel reduction of the dense STD factor gradients
(they are (I_n, J) dense after segment reduction — exactly the shape DP
all-reduces move). Error feedback keeps the quantization residual locally
and re-adds it next step, which preserves SGD convergence (Karimireddy et
al., 2019).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_ef(grad: jax.Array, error: jax.Array):
    """(grad + carried error) → (int8 q, per-row scale, new error)."""
    g = grad + error
    scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(grad.dtype) * scale
    new_error = g - deq
    return q, scale, new_error


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(scale.dtype) * scale


def compression_ratio(shape, dtype_bytes: int = 4) -> float:
    """int8 payload + per-row fp32 scale vs raw."""
    rows, cols = shape[-2], shape[-1]
    raw = rows * cols * dtype_bytes
    comp = rows * cols * 1 + rows * 4
    return raw / comp
