from . import adamw
from .adamw import AdamWConfig, AdamWState

__all__ = ["adamw", "AdamWConfig", "AdamWState"]
