"""Sharded checkpointing: two-phase commit, async writes, elastic resume.

Layout (orbax-free, npz-per-leaf):

    <dir>/step_000123.tmp/        # leaves + staged manifest, written first
        leaf_000000.npy ...
        manifest.json.staged
    <dir>/step_000123/            # os.replace'd into place
        manifest.json             # commit marker, os.replace'd LAST

A step is committed if and only if ``manifest.json`` exists in its final
directory — the marker lands in one atomic ``os.replace`` after every
leaf is durably in place, so a kill at ANY point mid-save leaves
``latest_step()`` on the previous commit (markerless debris is swept by
the next save's gc).  Re-saving an existing step decommits it first
(marker unlink, also atomic) — a kill inside that window falls back to
the commit before it, never to a half-written tree.

Restore tolerates a DIFFERENT device topology than the writer (elastic
resume): arrays are loaded on host and re-placed with whatever shardings
the new mesh dictates. ``keep`` bounds disk usage; writes can run on a
background thread (training continues — fault tolerance requires the
checkpoint cadence to hide write latency).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
        treedef = jax.tree.structure(tree)
        if blocking:
            self._write(step, host_leaves, treedef)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef),
                daemon=True,
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves: list, treedef) -> None:
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "num_leaves": len(leaves),
            "written_at": time.time(),
            "leaves": [
                {"shape": list(x.shape), "dtype": str(x.dtype)}
                for x in leaves
            ],
        }
        for i, x in enumerate(leaves):
            np.save(tmp / f"leaf_{i:06d}.npy", x)
        # the manifest is the commit marker: stage it under a non-marker
        # name so the step cannot look committed until the very last rename
        (tmp / "manifest.json.staged").write_text(json.dumps(manifest))
        if final.exists():
            # decommit (atomic marker unlink) BEFORE clearing: a kill
            # mid-rmtree leaves an uncommitted dir, never a corrupt commit
            (final / "manifest.json").unlink(missing_ok=True)
            shutil.rmtree(final)
        os.replace(tmp, final)
        # atomic commit: the marker appears only with every leaf in place
        os.replace(final / "manifest.json.staged", final / "manifest.json")
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
        # crash debris: staging dirs and markerless (uncommitted) steps.
        # No writer is concurrent here — save() serializes on wait() and
        # _gc runs on the writing thread — so anything markerless is dead.
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and (
                    p.name.endswith(".tmp")
                    or not (p / "manifest.json").exists()):
                shutil.rmtree(p, ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and not p.name.endswith(".tmp") \
                    and (p / "manifest.json").exists():
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_leaves(self, step: int | None = None
                    ) -> tuple[dict, list[np.ndarray]]:
        """Raw (manifest, leaves) of a committed step — no ``like`` tree.

        Readers that don't share the writer's pytree classes (e.g. the
        serving engine loading factors out of a ``DistState`` checkpoint)
        identify leaves by shape/position from the manifest instead.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = [
            np.load(d / f"leaf_{i:06d}.npy")
            for i in range(manifest["num_leaves"])
        ]
        return manifest, leaves

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Load into the structure of ``like``; re-place per ``shardings``
        (elastic: the writing mesh need not match the reading mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(like)
        assert manifest["num_leaves"] == len(leaves), (
            f"checkpoint has {manifest['num_leaves']} leaves, "
            f"expected {len(leaves)} — incompatible state structure"
        )
        loaded = [
            np.load(d / f"leaf_{i:06d}.npy") for i in range(len(leaves))
        ]
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings)
            loaded = [
                jax.device_put(x, s) for x, s in zip(loaded, sh_leaves)
            ]
        else:
            loaded = [jax.numpy.asarray(x) for x in loaded]
        return jax.tree.unflatten(treedef, loaded), step
